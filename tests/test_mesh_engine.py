"""Unified mesh engine (tier-1, CPU): a 1-device jax mesh must
bit-match the shared-memory engine for every granularity × policy,
logical multi-shard runs must agree too, the `mine_distributed` compat
shim must reproduce the old two-policy contract, and a forced
cross-device bucket steal must migrate (and account) the bucket's
retained arena bitmaps."""
import threading

import numpy as np
import pytest

from repro.core.fpm import mine, mine_serial
from repro.core.scheduler import ClusteredPolicy, TaskScheduler
from repro.core.tidlist import BitmapArena, pack_database
from repro.data.transactions import load

GRANULARITIES = ["bucket", "candidate", "depth-first"]
POLICIES = ["cilk", "fifo", "clustered", "nn"]


@pytest.fixture(scope="module")
def dataset():
    db, p = load("mushroom", seed=0)
    db = db[:250]
    bm = pack_database(db, p.n_dense_items)
    ms = int(0.22 * len(db))
    return bm, ms, mine_serial(bm, ms, max_k=4)


@pytest.fixture(scope="module")
def one_device_mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",))


# ------------------------------------------------ support equivalence
@pytest.mark.parametrize("granularity", GRANULARITIES)
@pytest.mark.parametrize("policy", POLICIES)
def test_one_device_mesh_matches_shared_memory(dataset, one_device_mesh,
                                               policy, granularity):
    """The acceptance matrix: a 1-device mesh runs the identical code
    path (sharded arena with one shard, per-device dispatcher, affine
    workers) and must bit-match the non-mesh engine."""
    bm, ms, ref = dataset
    got, met = mine(bm, ms, policy=policy, n_workers=3, max_k=4,
                    granularity=granularity, mesh=one_device_mesh)
    assert got == ref, (policy, granularity)
    assert met.n_devices == 1
    assert met.d2d_bytes == 0          # one shard: nothing is foreign
    assert len(met.per_device) == 1
    if granularity == "depth-first":
        assert met.cache_misses == 0   # handoff survives the mesh path


@pytest.mark.parametrize("granularity", GRANULARITIES)
def test_logical_two_shard_mesh_matches(dataset, granularity):
    """mesh=N (logical shards) exercises ownership, per-device
    dispatchers, and d2d accounting without needing >1 jax device —
    supports must still be identical."""
    bm, ms, ref = dataset
    got, met = mine(bm, ms, policy="clustered", n_workers=4, max_k=4,
                    granularity=granularity, mesh=2)
    assert got == ref, granularity
    assert met.n_devices == 2
    assert len(met.per_device) == 2
    assert sum(d["sweep_requests"] for d in met.per_device) == \
        met.scheduler["sweeps_submitted"]


def test_mesh_raises_on_bad_shard_count(dataset):
    bm, ms, _ = dataset
    with pytest.raises(ValueError, match="mesh"):
        mine(bm, ms, mesh=0)


def test_mesh_raises_fewer_workers_than_shards_grows(dataset):
    """n_workers is raised to cover every shard (a shard without a
    worker would starve its dispatcher)."""
    bm, ms, ref = dataset
    got, met = mine(bm, ms, n_workers=1, max_k=3, mesh=3)
    assert got == {k: v for k, v in ref.items() if len(k) <= 3}
    assert met.n_devices == 3


# ------------------------------------------------------- compat shim
def test_mine_distributed_wrapper_matches_serial(dataset,
                                                 one_device_mesh):
    from repro.core.distributed_fpm import mine_distributed
    bm, ms, ref = dataset
    rows = {}
    for pol in ("clustered", "round_robin"):
        got, stats = mine_distributed(bm, ms, one_device_mesh,
                                      policy=pol, max_k=4)
        assert got == ref, pol
        for key in ("levels", "candidates", "rows_touched",
                    "bytes_swept", "d2d_bytes", "migrations"):
            assert key in stats, key
        rows[pol] = stats["rows_touched"]
    # the paper's locality claim through the unified path: clustered
    # bucket placement reads fewer bitmap rows than scattered
    # full-join candidates
    assert rows["clustered"] < rows["round_robin"]


def test_mine_distributed_rejects_unknown_policy(dataset,
                                                 one_device_mesh):
    from repro.core.distributed_fpm import mine_distributed
    bm, ms, _ = dataset
    with pytest.raises(ValueError):
        mine_distributed(bm, ms, one_device_mesh, policy="nope")


# ------------------------------------------- steal-as-migration path
def test_forced_cross_device_steal_migrates_handles():
    """Deterministic cross-device bucket steal: one worker blocks
    inside a bucket, a second bucket carrying an arena handle lands on
    that SAME worker's queue, and the only idle worker (the other
    shard) must steal it — the migration callback re-owners the handle
    onto the thief's shard and the transfer lands in d2d_bytes. The
    blocker itself may be stolen before its home worker dequeues it,
    so the test pins the carrier (and the handle's owner shard) to
    wherever the blocker actually ran."""
    rows = np.random.default_rng(3).integers(
        0, 2 ** 32, size=(4, 8), dtype=np.uint32)
    arena = BitmapArena.from_bitmaps(rows, backing="numpy", n_shards=2)

    pol = ClusteredPolicy(2, cluster_of=lambda a: a)
    sched = TaskScheduler(2, pol, device_of=[0, 1],
                          migrate_cb=lambda hs, src, dst:
                              arena.migrate(hs, dst))
    stolen_ran_on = []
    where = {}
    started = threading.Event()
    migrated = threading.Event()
    orig_migrate = arena.migrate

    def spy_migrate(hs, dst):
        n = orig_migrate(hs, dst)
        migrated.set()
        return n

    arena.migrate = spy_migrate

    def blocker():
        where["victim"] = sched.worker_device()
        started.set()
        migrated.wait(timeout=10)

    def carrier():
        stolen_ran_on.append(sched.worker_device())

    sched.spawn(blocker, attr=0, worker=0)
    assert started.wait(timeout=5)
    victim = where["victim"]                 # blocker's actual worker
    thief = 1 - victim                       # the only idle worker
    h = arena.materialize(0, 1, shard=victim)
    # the carrier bucket lands on the busy worker's queue, so the only
    # path to it is the other worker's (cross-device) steal
    sched.spawn(carrier, attr=1, worker=victim, handles=(h,))
    sched.wait_all()
    sched.shutdown()

    assert migrated.is_set(), "cross-device steal never migrated"
    assert stolen_ran_on == [thief]          # ran on the thief's shard
    assert arena.owner_of(h) == thief
    assert arena.d2d_bytes == arena.n_words * 4
    assert arena.migrations == 1
    # >= 1: the blocker bucket itself may also have been stolen
    # cross-device (a second EVENT that carried no handles)
    assert sched.merged_stats()["steal_migrations"] >= 1


def test_same_device_steal_does_not_migrate():
    """Steals inside one shard are the cheap path: no migration, no
    d2d accounting."""
    rows = np.random.default_rng(4).integers(
        0, 2 ** 32, size=(3, 4), dtype=np.uint32)
    arena = BitmapArena.from_bitmaps(rows, backing="numpy", n_shards=1)
    h = arena.materialize(0, 1, shard=0)
    calls = []
    pol = ClusteredPolicy(2, cluster_of=lambda a: a)
    sched = TaskScheduler(2, pol, device_of=[0, 0],
                          migrate_cb=lambda hs, src, dst:
                              calls.append(hs))
    done = threading.Event()
    sched.spawn(lambda: done.wait(timeout=2), attr=0, worker=0)
    sched.spawn(done.set, attr=1, worker=0, handles=(h,))
    sched.wait_all()
    sched.shutdown()
    assert calls == []
    assert arena.d2d_bytes == 0 and arena.migrations == 0


# --------------------------------------------------- engine-level d2d
def test_depth_first_logical_mesh_records_d2d_on_steals():
    """End-to-end: depth-first mining over 2 logical shards with an
    imbalanced clustered placement — every steal that crosses shards
    migrates handoff bitmaps, so migrations and d2d move together and
    supports stay exact."""
    db, p = load("mushroom", seed=1)
    db = db[:400]
    bm = pack_database(db, p.n_dense_items)
    ms = int(0.2 * len(db))
    ref = mine_serial(bm, ms, max_k=4)
    got, met = mine(bm, ms, policy="clustered", n_workers=2, max_k=4,
                    granularity="depth-first", mesh=2)
    assert got == ref
    assert met.cache_misses == 0
    # steal timing is nondeterministic, so only structural properties
    # hold: traffic is whole rows, and a re-steal of a still-resident
    # row can flip ownership for free, so migrations may legitimately
    # exceed the billed crossings (the deterministic d2d > 0 case is
    # test_forced_cross_device_steal_migrates_handles)
    assert met.d2d_bytes % (bm.shape[1] * 4) == 0
    assert met.d2d_bytes >= 0 and met.migrations >= 0
    assert met.scheduler["steal_migrations"] >= 0  # steal-dependent;
                                             # gauge wired
